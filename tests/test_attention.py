"""Attention layer unit tests: GQA reference, sliding window, rope,
prefill/decode cache consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionConfig
from repro.models import attention as attn
from repro.models.rope import apply_rope


def _cfg(**kw):
    base = dict(n_heads=4, n_kv_heads=2, head_dim=16)
    base.update(kw)
    return AttentionConfig(**base)


def test_sdpa_matches_naive(key):
    B, S, H, hd = 2, 8, 4, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    mask = attn._causal_mask(S, None)
    out = attn._sdpa(q, k, v, mask)
    # naive per-head
    logits = jnp.einsum("bshd,bthd->bhst", q, k) / np.sqrt(hd)
    logits = jnp.where(mask[None, None], logits, -1e30)
    ref = jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(logits, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_gqa_grouping(key):
    """With KV heads repeated, GQA == MHA on the expanded heads."""
    B, S, H, KV, hd = 1, 6, 4, 2, 8
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    mask = attn._causal_mask(S, None)
    out = attn._sdpa(q, k, v, mask)
    k_full = jnp.repeat(k, H // KV, axis=2)
    v_full = jnp.repeat(v, H // KV, axis=2)
    # repeat along head axis groups: heads [0,1] use kv0, [2,3] use kv1
    # _sdpa reshape: (KV, rep) ordering -> head h uses kv h // rep
    ref = attn._sdpa(
        q.reshape(B, S, KV, H // KV, hd).reshape(B, S, H, hd),
        k_full, v_full, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_sliding_window_restricts_context(key):
    S, W = 16, 4
    mask = attn._causal_mask(S, W)
    i, j = np.meshgrid(np.arange(S), np.arange(S), indexing="ij")
    expected = (j <= i) & (j > i - W)
    np.testing.assert_array_equal(np.asarray(mask), expected)


def test_rope_preserves_norm_and_relativity(key):
    B, S, H, hd = 1, 8, 2, 16
    x = jax.random.normal(key, (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    r = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(r), axis=-1), rtol=1e-5)
    # Relative property: <R(p)q, R(p+d)k> depends only on d.
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 1, hd))
    def dot_at(p, d):
        rq = apply_rope(q, jnp.full((1, 1), p), 10000.0)
        rk = apply_rope(k, jnp.full((1, 1), p + d), 10000.0)
        return float(jnp.sum(rq * rk))
    assert abs(dot_at(3, 5) - dot_at(10, 5)) < 1e-4


@pytest.mark.parametrize("window", [None, 8])
def test_prefill_decode_consistency(key, window):
    """Decoding token-by-token equals the full causal forward."""
    cfg = _cfg(sliding_window=window)
    d_model = 32
    p = attn.init_attention(key, d_model, cfg)
    B, S = 2, 12
    x = jax.random.normal(key, (B, S + 1, d_model))
    pos = jnp.broadcast_to(jnp.arange(S + 1)[None], (B, S + 1))
    full = attn.attention_forward(p, x, cfg, pos)
    out_pre, cache = attn.attention_prefill(
        p, x[:, :S], cfg, pos[:, :S], attn.init_kv_cache(B, S + 4, cfg,
                                                         jnp.float32))
    np.testing.assert_allclose(
        np.asarray(out_pre), np.asarray(full[:, :S]), atol=1e-5)
    out_dec, _ = attn.attention_decode_step(
        p, x[:, S:S + 1], cfg, jnp.asarray(S), cache)
    np.testing.assert_allclose(
        np.asarray(out_dec[:, 0]), np.asarray(full[:, S]), atol=1e-4)
