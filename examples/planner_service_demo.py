"""Planner service demo: replanning (b, V) over a production trace.

Walks the `diurnal_edge` trace scenario (the population behind the
`mnist_diurnal` registry spec: phone/tablet/IoT classes, battery/thermal
gates, time-of-day availability) with the online planner service
(federated/planner.py): each epoch the service re-solves the talk/work
operating point from the previous epoch's telemetry — all epochs batched
into ONE vectorized KKT dispatch — and the report scores the replanned
sequence against every fixed plan on simulated time-to-target over the
SAME realized rounds, quoting the regret vs the hindsight oracle.

  PYTHONPATH=src python examples/planner_service_demo.py \
      [--quick] [--check] [--json PATH] [--seed N]

--check exits 1 unless the replanned sequence beats the worst fixed plan
(the acceptance bar: adapting must dominate the worst static choice).
--json writes the full regret report (the CI planner-smoke artifact).
"""
from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0])

from repro.configs.base import FedConfig  # noqa: E402
from repro.federated import experiment, planner  # noqa: E402


def run(quick: bool = False, seed: int = 0) -> planner.ReplanReport:
    # The trace fed: mnist_diurnal's population/constants, but a looser
    # epsilon so the Eq. 12 budget is reachable inside a short demo trace
    # (epsilon=0.01 needs thousands of rounds; the *relative* ordering of
    # plans is what the demo exercises).
    spec = experiment.get("mnist_diurnal")
    fed = FedConfig(n_devices=spec.n_devices(), epsilon=0.1, nu=2.0,
                    c=1.0, lr=0.05)
    epochs, rounds = (4, 8) if quick else (6, 16)
    return planner.replan_trace(
        "diurnal_edge", fed, update_bits=spec.update_bits(),
        epochs=epochs, rounds_per_epoch=rounds, seed=seed)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter trace (4 epochs x 8 rounds)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless replanning beats the worst fixed "
                         "plan on simulated time-to-target")
    ap.add_argument("--json", default="",
                    help="write the regret report JSON here (CI artifact)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    report = run(quick=args.quick, seed=args.seed)
    print(f"scenario: {report.scenario}  "
          f"({report.epochs} epochs x {report.rounds_per_epoch} rounds)")
    print("per-epoch operating points:")
    for p in report.plans:
        print(f"  epoch {p.epoch}: b={p.b:<3d} V={p.V:<2d} "
              f"participation={p.participation:.2f} "
              f"T_round_pred={p.T_round_pred:.3f}s")
    print(report.table())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report.to_json(), f, indent=2, default=float)
            f.write("\n")
    if args.check:
        if not report.beats_worst():
            print(f"FAIL: replanned {report.replanned_time:.2f}s does not "
                  f"beat worst fixed plan {report.worst} "
                  f"({report.worst_time:.2f}s)")
            raise SystemExit(1)
        print(f"check: replanned {report.replanned_time:.2f}s beats worst "
              f"fixed {report.worst} ({report.worst_time:.2f}s); regret vs "
              f"oracle {report.oracle} = {report.regret:+.2f}s")


if __name__ == "__main__":
    main()
