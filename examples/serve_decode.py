"""Serving example (deliverable b): batched prefill + token-by-token decode
against KV/SSM caches, across architecture families.

  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch import serve

for arch in ["qwen2-0.5b", "falcon-mamba-7b", "zamba2-2.7b"]:
    print(f"=== {arch} ===")
    serve.main(["--arch", arch, "--smoke", "--batch", "2",
                "--prompt-len", "32", "--gen", "8"])
