"""Asynchronous vs synchronous FL: DEFL's synchronized rounds vs
FedBuff-style buffered aggregation (backend='async') on the paper's CNN
task — time to 90% accuracy per edge scenario.

  PYTHONPATH=src python examples/async_vs_sync.py [--quick] \
      [--scenario stragglers] [--seeds 8] [--json PATH] \
      [--checkpoint-dir DIR] [--no-resume]

Each scenario comparison is one declarative Study
(benchmarks/async_vs_sync.study_for): the sync DEFL arm runs the grouped
fleet path while async arms run solo on the compiled event queue (one
RoundRecord per buffer fill, sim_time on the event clock) — so the
time-to-target columns compare like-for-like wall clock. Full runs add a
FedBuff+ arm re-planned under the async Eq. 12 re-derivation
(defl.async_plan). Without --scenario the registered trio (uniform,
stragglers, dropout) is swept; --json dumps the StudyResult payloads."""
import argparse
import json
import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0])

from benchmarks.async_vs_sync import SCENARIO_NAMES, run  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scenario", default="", choices=("",) + SCENARIO_NAMES)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--json", default="",
                    help="write the StudyResult JSON payloads here")
    ap.add_argument("--checkpoint-dir", default="",
                    help="crash-safe per-(arm, seed) autosave: a killed "
                         "sweep resumes from the saved members "
                         "bit-identically")
    ap.add_argument("--no-resume", action="store_true",
                    help="with --checkpoint-dir: ignore existing member "
                         "checkpoints and re-run everything")
    args = ap.parse_args()
    header, rows, payload = run(quick=args.quick, scenario=args.scenario,
                                seeds=args.seeds,
                                checkpoint_dir=args.checkpoint_dir,
                                resume=not args.no_resume)
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, default=float)
            f.write("\n")


if __name__ == "__main__":
    main()
