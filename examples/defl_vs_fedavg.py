"""End-to-end driver (deliverable b): DEFL vs FedAvg vs Rand on the
paper's CNN task with real training + simulated delay accounting —
reproduces Fig. 2 qualitatively, per edge scenario.

  PYTHONPATH=src python examples/defl_vs_fedavg.py [--quick] \
      [--scenario stragglers] [--seeds 8]

Without --scenario the full registered table (uniform, stragglers,
cell_edge, dropout, drifting) is swept; --seeds N runs every method as a
vmapped N-seed fleet (one dispatch per chunk executes all seeds) and
reports mean +/- std confidence bands over the realizations."""
import argparse
import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0])

from benchmarks.fig2_defl_vs_fedavg import run  # noqa: E402
from repro.federated import scenarios  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scenario", default="",
                    choices=("",) + scenarios.names())
    ap.add_argument("--seeds", type=int, default=1)
    args = ap.parse_args()
    header, rows = run(quick=args.quick, scenario=args.scenario,
                       seeds=args.seeds)
    print(header)
    for r in rows:
        print(",".join(map(str, r)))


if __name__ == "__main__":
    main()
