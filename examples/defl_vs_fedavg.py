"""End-to-end driver (deliverable b): DEFL vs FedAvg vs Rand on the
paper's CNN task with real training + simulated delay accounting —
reproduces Fig. 2 qualitatively, per edge scenario.

  PYTHONPATH=src python examples/defl_vs_fedavg.py [--quick] \
      [--scenario stragglers] [--seeds 8] [--json PATH] \
      [--checkpoint-dir DIR] [--no-resume]

Each (scenario, dataset) comparison is one declarative Study
(benchmarks/fig2_defl_vs_fedavg.study_for): the DEFL/FedAvg/Rand arms
run as a single grouped vmapped fleet over the (arm x seed) axis with
in-fleet 90%-accuracy early stopping. Without --scenario the full
registered table (uniform, stragglers, cell_edge, dropout, drifting) is
swept; --seeds N widens every arm to N realization seeds (mean +- std
confidence bands); --json dumps the full StudyResult payloads."""
import argparse
import json
import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0])

from benchmarks.fig2_defl_vs_fedavg import run  # noqa: E402
from repro.federated import scenarios  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scenario", default="",
                    choices=("",) + scenarios.names())
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--json", default="",
                    help="write the StudyResult JSON payloads here")
    ap.add_argument("--checkpoint-dir", default="",
                    help="crash-safe per-(arm, seed) autosave: a killed "
                         "sweep resumes from the saved members "
                         "bit-identically")
    ap.add_argument("--no-resume", action="store_true",
                    help="with --checkpoint-dir: ignore existing member "
                         "checkpoints and re-run everything")
    args = ap.parse_args()
    header, rows, payload = run(quick=args.quick, scenario=args.scenario,
                                seeds=args.seeds,
                                checkpoint_dir=args.checkpoint_dir,
                                resume=not args.no_resume)
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, default=float)
            f.write("\n")


if __name__ == "__main__":
    main()
