"""Multi-pod demo: lower + compile one architecture on the 2x16x16
(512-chip) production mesh and print its memory/cost analyses.

  PYTHONPATH=src python examples/multipod_dryrun_demo.py [arch] [shape]
"""
import subprocess
import sys
import os

arch = sys.argv[1] if len(sys.argv) > 1 else "gemma-7b"
shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
# Subprocess so the 512-device XLA flag never leaks into the caller.
sys.exit(subprocess.call(
    [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
     "--shape", shape, "--mesh", "multi", "--out", "/tmp/multipod_demo"],
    env=env))
