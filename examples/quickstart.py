"""Quickstart: DEFL in ~60 lines.

1. Build the paper's delay problem from a device population.
2. Solve for (b*, theta*) with the closed-form KKT solution (Eq. 29).
3. Run federated training with V = nu*log(1/theta*) local steps per round,
   tracking the simulated wall clock.

  PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import defl, delay
from repro.data import BatchIterator, make_mnist_like
from repro.federated.partition import partition_dirichlet, partition_sizes
from repro.federated.simulation import FLSimulation
from repro.models import cnn
from repro.optim import sgd
from repro.utils.tree import tree_bytes


def main():
    # --- system: 10 edge devices, 2 GHz GPUs, 20 MHz uplink --------------
    fed = FedConfig(n_devices=10, epsilon=0.01, nu=2.0, c=0.4, lr=0.05)
    pop = delay.draw_population(
        fed.n_devices, ComputeConfig(bits_per_sample=6.8e5),
        WirelessConfig(), seed=0, heterogeneity=0.2)

    # --- model + data -----------------------------------------------------
    cfg = cnn.mnist_cnn()
    params = cnn.init_cnn(cfg, jax.random.PRNGKey(0))
    data = make_mnist_like(1000, seed=0)

    # --- DEFL plan (Algorithm 1, line 0) ----------------------------------
    plan = defl.make_plan(fed, pop, tree_bytes(params) * 8)
    fed = defl.plan_to_fedconfig(plan, fed)
    fed = FedConfig(**{**fed.__dict__, "batch_size": min(fed.batch_size, 32),
                       "update_bytes": None})
    print(f"DEFL plan: b*={plan.b} theta*={plan.theta:.3f} V={plan.V} "
          f"H_pred={plan.H_pred:.1f} T_round={plan.T_round:.3f}s "
          f"overall_pred={plan.overall_pred:.1f}s")

    # --- run ---------------------------------------------------------------
    parts = partition_dirichlet(data, fed.n_devices, alpha=1.0, seed=0)
    iters = [BatchIterator(data, p, fed.batch_size, seed=i)
             for i, p in enumerate(parts)]
    sim = FLSimulation(
        functools.partial(cnn.cnn_loss, cfg), params, iters,
        partition_sizes(parts), fed, sgd(fed.lr), pop, label="defl")
    res = sim.run(max_rounds=5)
    for r in res.history:
        print(f"round {r.round}: sim_time={r.sim_time:7.2f}s "
              f"loss={r.train_loss:.4f}")


if __name__ == "__main__":
    main()
