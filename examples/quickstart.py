"""Quickstart: DEFL through the declarative experiment API.

1. Describe the experiment as a frozen `ExperimentSpec` (model, data,
   population, wireless — and `plan=True` to solve the paper's (b*,
   theta*) against the realized population, Alg. 1 line 0).
2. `spec.build()` -> a pure functional `Simulator`; `sim.init(seed)` ->
   an immutable `SimState`; `sim.run(state, ...)` threads it through real
   training while tracking the simulated wall clock (Eq. 8).
3. `sim.run_fleet(seeds=...)` runs a multi-seed fleet in ONE vmapped
   dispatch per round-chunk — the confidence-band workload.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from repro.configs.base import FedConfig  # noqa: E402
from repro.federated.experiment import ExperimentSpec  # noqa: E402


def main():
    # --- the experiment, declaratively ------------------------------------
    spec = ExperimentSpec(
        fed=FedConfig(n_devices=10, epsilon=0.01, nu=2.0, c=0.4, lr=0.05),
        model="mnist_cnn", dataset="mnist", n_train=1000,
        heterogeneity=0.2, plan=True, with_eval=False, label="defl")

    plan = spec.resolve_plan()
    print(f"DEFL plan: b*={plan.b} theta*={plan.theta:.3f} V={plan.V} "
          f"H_pred={plan.H_pred:.1f} T_round={plan.T_round:.3f}s "
          f"overall_pred={plan.overall_pred:.1f}s")

    # --- one run: state-in / state-out ------------------------------------
    sim = spec.build()
    state, res = sim.run(sim.init(), max_rounds=5)
    for r in res.history:
        print(f"round {r.round}: sim_time={r.sim_time:7.2f}s "
              f"loss={r.train_loss:.4f}")

    # --- a 4-seed fleet: one vmapped dispatch per chunk -------------------
    fleet = sim.run_fleet(seeds=range(4), max_rounds=5, eval_every=5)
    s = fleet.summary()
    print(f"fleet over 4 seeds: final loss "
          f"{s['final_loss_mean']:.4f} +- {s['final_loss_std']:.4f}, "
          f"overall time {s['total_time_mean']:.1f}s "
          f"+- {s['total_time_std']:.1f}s")


if __name__ == "__main__":
    main()
